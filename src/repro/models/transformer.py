"""Model assembly for all six families (dense / moe / ssm / hybrid / encdec / vlm).

All layer stacks are ``lax.scan``-ed over stacked parameters (leading layer
axis) so the HLO stays small and compile time flat in depth — required for
the 61-layer / 671B dry-run. Remat policy ("none" | "dots" | "full") wraps
the scanned layer body.

``apply_lm``         : full-sequence forward -> (logits, aux)  [train/prefill]
``apply_lm_decode``  : one-token forward with caches -> (logits, new_caches)
``init_lm``/``init_caches`` build the matching parameter / cache pytrees.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding.ctx import shard


def _dt(cfg):
    return L.dtype_of(cfg.param_dtype)


def _cdt(cfg):
    return L.dtype_of(cfg.compute_dtype)


def _remat(fn, mode: str):
    if mode == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


# ---------------------------------------------------------------------------
# per-family layer init
# ---------------------------------------------------------------------------

def _init_dense_layer(cfg, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": A.init_attention(k1, cfg, dtype=dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
        }
    return init


def _init_moe_layer(cfg, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        attn = (A.init_mla(k1, cfg, dtype) if cfg.attention == "mla"
                else A.init_attention(k1, cfg, dtype=dtype))
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": attn,
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "moe": M.init_moe(k2, cfg, dtype),
        }
    return init


def _init_moe_dense_layer(cfg, dtype):
    """DeepSeek first_k_dense layers: MLA attention + dense MLP."""
    def init(key):
        k1, k2 = jax.random.split(key)
        attn = (A.init_mla(k1, cfg, dtype) if cfg.attention == "mla"
                else A.init_attention(k1, cfg, dtype=dtype))
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": attn,
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
        }
    return init


def _init_ssm_layer(cfg, dtype):
    def init(key):
        return {"ln": L.init_rmsnorm(cfg.d_model, dtype),
                "ssm": S.init_ssm(key, cfg, dtype)}
    return init


def _init_shared_block(cfg, key, dtype):
    """Zamba2 shared attention block over concat(hidden, embed0) = 2*d_model."""
    k1, k2 = jax.random.split(key)
    Dc = 2 * cfg.d_model
    return {
        "ln1": L.init_rmsnorm(Dc, dtype),
        "attn": A.init_attention(k1, cfg, d_in=Dc, dtype=dtype),
        "ln2": L.init_rmsnorm(Dc, dtype),
        "mlp": {"gate": L.dense_init(jax.random.fold_in(k2, 0), Dc, cfg.d_ff, dtype),
                "up": L.dense_init(jax.random.fold_in(k2, 1), Dc, cfg.d_ff, dtype),
                "down": L.dense_init(jax.random.fold_in(k2, 2), cfg.d_ff, cfg.d_model, dtype)},
    }


def _init_encdec_dec_layer(cfg, dtype):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "self_attn": A.init_attention(k1, cfg, dtype=dtype),
            "ln_x": L.init_rmsnorm(cfg.d_model, dtype),
            "cross_attn": A.init_attention(k2, cfg, dtype=dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype),
        }
    return init


# ---------------------------------------------------------------------------
# init_lm
# ---------------------------------------------------------------------------

def init_lm(key, cfg) -> Dict[str, Any]:
    dtype = _dt(cfg)
    V, D = cfg.padded_vocab, cfg.d_model
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": L.init_embed(ks[0], V, D, dtype),
                              "final_norm": L.init_rmsnorm(D, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L.dense_init(ks[1], D, V, dtype)}

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = L.stack_init(_init_dense_layer(cfg, dtype), ks[2],
                                        cfg.num_layers)
    elif fam == "moe":
        n_moe = cfg.num_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            params["dense_layers"] = L.stack_init(
                _init_moe_dense_layer(cfg, dtype), ks[3], cfg.first_k_dense)
        params["layers"] = L.stack_init(_init_moe_layer(cfg, dtype), ks[2], n_moe)
        if cfg.mtp_depth:
            km = jax.random.split(ks[4], 3)
            params["mtp"] = {
                "proj": L.dense_init(km[0], 2 * D, D, dtype),
                "norm_h": L.init_rmsnorm(D, dtype),
                "norm_e": L.init_rmsnorm(D, dtype),
                "block": _init_dense_layer(
                    cfg.replace(d_ff=cfg.moe_d_ff * cfg.experts_per_token),
                    dtype)(km[1]),
            }
    elif fam == "ssm":
        params["layers"] = L.stack_init(_init_ssm_layer(cfg, dtype), ks[2],
                                        cfg.num_layers)
    elif fam == "hybrid":
        G = cfg.num_layers // cfg.shared_attn_interval
        leftover = cfg.num_layers - G * cfg.shared_attn_interval
        inner = _init_ssm_layer(cfg, dtype)

        def group_init(k):
            return L.stack_init(inner, k, cfg.shared_attn_interval)
        params["groups"] = L.stack_init(group_init, ks[2], G)
        if leftover:
            params["leftover"] = L.stack_init(inner, ks[5], leftover)
        params["shared"] = _init_shared_block(cfg, ks[6], dtype)
    elif fam == "encdec":
        params["enc_layers"] = L.stack_init(_init_dense_layer(cfg, dtype),
                                            ks[2], cfg.num_enc_layers)
        params["dec_layers"] = L.stack_init(_init_encdec_dec_layer(cfg, dtype),
                                            ks[3], cfg.num_layers)
        params["enc_norm"] = L.init_rmsnorm(D, dtype)
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# full-sequence bodies
# ---------------------------------------------------------------------------

def _dense_body(cfg, lp, h, positions, prefix_len=None):
    h = h + A.apply_attention_full(lp["attn"], cfg,
                                   L.apply_rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                   positions, prefix_len)
    h = h + L.apply_mlp(lp["mlp"], L.apply_rmsnorm(lp["ln2"], h, cfg.norm_eps),
                        cfg.act)
    return shard(h, "batch", None, None)


def _moe_dense_body(cfg, lp, h, positions):
    """DeepSeek first_k_dense layers: MLA (or GQA) attention + dense MLP."""
    attn_in = L.apply_rmsnorm(lp["ln1"], h, cfg.norm_eps)
    if cfg.attention == "mla":
        h = h + A.apply_mla_full(lp["attn"], cfg, attn_in, positions)
    else:
        h = h + A.apply_attention_full(lp["attn"], cfg, attn_in, positions)
    h = h + L.apply_mlp(lp["mlp"], L.apply_rmsnorm(lp["ln2"], h, cfg.norm_eps),
                        cfg.act)
    return shard(h, "batch", None, None)


def _moe_body(cfg, lp, h, positions):
    attn_in = L.apply_rmsnorm(lp["ln1"], h, cfg.norm_eps)
    if cfg.attention == "mla":
        h = h + A.apply_mla_full(lp["attn"], cfg, attn_in, positions)
    else:
        h = h + A.apply_attention_full(lp["attn"], cfg, attn_in, positions)
    y, aux = M.apply_moe(lp["moe"], cfg,
                         L.apply_rmsnorm(lp["ln2"], h, cfg.norm_eps))
    return shard(h + y, "batch", None, None), aux


def _ssm_body(cfg, lp, h):
    h = h + S.apply_ssm_full(lp["ssm"], cfg,
                             L.apply_rmsnorm(lp["ln"], h, cfg.norm_eps))
    return shard(h, "batch", None, None)


def _shared_body(cfg, sp, h, emb0, positions):
    c = jnp.concatenate([h, emb0], axis=-1)
    h = h + A.apply_attention_full(sp["attn"], cfg,
                                   L.apply_rmsnorm(sp["ln1"], c, cfg.norm_eps),
                                   positions)
    c2 = jnp.concatenate([h, emb0], axis=-1)
    m = L.apply_rmsnorm(sp["ln2"], c2, cfg.norm_eps)
    m = jax.nn.silu(m @ sp["mlp"]["gate"].astype(h.dtype)) * (m @ sp["mlp"]["up"].astype(h.dtype))
    return shard(h + m @ sp["mlp"]["down"].astype(h.dtype), "batch", None, None)


def _cross_attention(p, cfg, x, enc_out):
    """Full cross-attention (decoder queries over encoder keys)."""
    B, Sq, _ = x.shape
    Se = enc_out.shape[1]
    hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, Sq, H, hd).transpose(0, 2, 1, 3)
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, Se, KH, hd)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, Se, KH, hd)
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    qpos = jnp.zeros((Sq,), jnp.int32)
    kpos = jnp.zeros((Se,), jnp.int32)
    out = A.blockwise_attention(q, k, v, qpos, kpos, prefix_len=jnp.int32(1))
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, H * hd)
    return out @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# apply_lm (train / prefill)
# ---------------------------------------------------------------------------

def apply_lm(params, cfg, tokens, *, frames=None, patches=None,
             remat: str = "none") -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens: (B,S) int32. frames: (B,enc_S,D) [encdec]. patches: (B,P,D) [vlm].

    Returns (logits (B,S*,V), aux dict with 'moe_aux', optional 'mtp_logits').
    """
    cdt = _cdt(cfg)
    aux: Dict[str, Any] = {"moe_aux": jnp.zeros((), jnp.float32)}
    B, S = tokens.shape
    h = L.apply_embed({"table": params["embed"]["table"]}, tokens).astype(cdt)
    prefix_len = None

    if cfg.family == "vlm":
        h = jnp.concatenate([patches.astype(cdt), h], axis=1)
        prefix_len = jnp.int32(cfg.num_patches)
    h = shard(h, "batch", None, None)
    Stot = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Stot, dtype=jnp.int32)[None], (B, Stot))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        body = _remat(lambda hh, lp: (_dense_body(cfg, lp, hh, positions, prefix_len), None),
                      remat)
        h, _ = jax.lax.scan(lambda hh, lp: body(hh, lp), h, params["layers"])
    elif fam == "moe":
        if cfg.first_k_dense:
            dbody = _remat(
                lambda hh, lp: (_moe_dense_body(cfg, lp, hh, positions), None),
                remat)
            h, _ = jax.lax.scan(lambda hh, lp: dbody(hh, lp), h,
                                params["dense_layers"])

        def moe_step(carry, lp):
            hh, ax = carry
            hh, a = _moe_body(cfg, lp, hh, positions)
            return (hh, ax + a), None
        body = _remat(moe_step, remat)
        (h, moe_aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                       params["layers"])
        aux["moe_aux"] = moe_aux
        if cfg.mtp_depth and "mtp" in params:
            nxt = jnp.roll(tokens, -1, axis=1)
            e = L.apply_embed({"table": params["embed"]["table"]}, nxt).astype(cdt)
            m = jnp.concatenate([
                L.apply_rmsnorm(params["mtp"]["norm_h"], h, cfg.norm_eps),
                L.apply_rmsnorm(params["mtp"]["norm_e"], e, cfg.norm_eps)], -1)
            m = m @ params["mtp"]["proj"].astype(cdt)
            mcfg = cfg.replace(d_ff=cfg.moe_d_ff * cfg.experts_per_token)
            m = _dense_body(mcfg, params["mtp"]["block"], m, positions)
            m = L.apply_rmsnorm(params["final_norm"], m, cfg.norm_eps)
            aux["mtp_logits"] = _head(params, cfg, m)
    elif fam == "ssm":
        body = _remat(lambda hh, lp: (_ssm_body(cfg, lp, hh), None), remat)
        h, _ = jax.lax.scan(lambda hh, lp: body(hh, lp), h, params["layers"])
    elif fam == "hybrid":
        emb0 = h
        inner = _remat(lambda hh, lp: (_ssm_body(cfg, lp, hh), None), remat)

        def group_step(hh, gp):
            hh, _ = jax.lax.scan(lambda c, lp: inner(c, lp), hh, gp)
            hh = _shared_body(cfg, params["shared"], hh, emb0, positions)
            return hh, None
        h, _ = jax.lax.scan(group_step, h, params["groups"])
        if "leftover" in params:
            h, _ = jax.lax.scan(lambda c, lp: inner(c, lp), h, params["leftover"])
    elif fam == "encdec":
        he = frames.astype(cdt)
        Se = he.shape[1]
        epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))
        ebody = _remat(
            lambda hh, lp: (_dense_body(cfg, lp, hh, epos, prefix_len=jnp.int32(Se)), None),
            remat)
        he, _ = jax.lax.scan(lambda hh, lp: ebody(hh, lp), he, params["enc_layers"])
        he = L.apply_rmsnorm(params["enc_norm"], he, cfg.norm_eps)

        def dec_body_fn(hh, lp):
            hh = hh + A.apply_attention_full(
                lp["self_attn"], cfg, L.apply_rmsnorm(lp["ln1"], hh, cfg.norm_eps),
                positions)
            hh = hh + _cross_attention(
                lp["cross_attn"], cfg, L.apply_rmsnorm(lp["ln_x"], hh, cfg.norm_eps), he)
            hh = hh + L.apply_mlp(lp["mlp"],
                                  L.apply_rmsnorm(lp["ln2"], hh, cfg.norm_eps), cfg.act)
            return shard(hh, "batch", None, None), None
        dbody = _remat(dec_body_fn, remat)
        h, _ = jax.lax.scan(lambda hh, lp: dbody(hh, lp), h, params["dec_layers"])
    else:
        raise ValueError(fam)

    h = L.apply_rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _head(params, cfg, h)
    return logits, aux


def _head(params, cfg, h):
    if "lm_head" in params:
        logits = h @ params["lm_head"]["w"].astype(h.dtype)
    else:
        logits = h @ params["embed"]["table"].T.astype(h.dtype)
    return shard(logits.astype(jnp.float32), "batch", None, "vocab")


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def _stack_cache(make_one, n: int):
    one = make_one()
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)


def init_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"layers": _stack_cache(
            lambda: A.init_kv_cache(cfg, batch, max_len, dtype), cfg.num_layers)}
    if fam == "moe":
        n_moe = cfg.num_layers - cfg.first_k_dense
        mk = ((lambda: A.init_mla_cache(cfg, batch, max_len, dtype))
              if cfg.attention == "mla"
              else (lambda: A.init_kv_cache(cfg, batch, max_len, dtype)))
        c = {"layers": _stack_cache(mk, n_moe)}
        if cfg.first_k_dense:
            c["dense_layers"] = _stack_cache(mk, cfg.first_k_dense)
        return c
    if fam == "ssm":
        return {"layers": _stack_cache(
            lambda: S.init_ssm_cache(cfg, batch), cfg.num_layers)}
    if fam == "hybrid":
        G = cfg.num_layers // cfg.shared_attn_interval
        leftover = cfg.num_layers - G * cfg.shared_attn_interval
        c = {"groups": _stack_cache(
                lambda: _stack_cache(lambda: S.init_ssm_cache(cfg, batch),
                                     cfg.shared_attn_interval), G),
             "shared": _stack_cache(
                lambda: A.init_kv_cache(cfg, batch, max_len, dtype), G)}
        if leftover:
            c["leftover"] = _stack_cache(
                lambda: S.init_ssm_cache(cfg, batch), leftover)
        return c
    if fam == "encdec":
        return {"self": _stack_cache(
                    lambda: A.init_kv_cache(cfg, batch, max_len, dtype),
                    cfg.num_layers),
                "cross": _stack_cache(
                    lambda: A.init_kv_cache(cfg, batch, cfg.enc_seq, dtype),
                    cfg.num_layers)}
    raise ValueError(fam)


def _cross_attention_decode(p, cfg, x, kc, vc):
    B = x.shape[0]
    hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, KH, H // KH, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) * hd ** -0.5
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", w.astype(vc.dtype), vc)
    return o.reshape(B, 1, H * hd).astype(dt) @ p["wo"].astype(dt)


def apply_lm_decode(params, cfg, token, caches, index):
    """token: (B,1) int32; index: scalar int32 current position.

    Returns (logits (B,1,V), new_caches).
    """
    cdt = _cdt(cfg)
    B = token.shape[0]
    h = L.apply_embed({"table": params["embed"]["table"]}, token).astype(cdt)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def step(hh, xs):
            lp, cache = xs
            a, nc = A.apply_attention_decode(
                lp["attn"], cfg, L.apply_rmsnorm(lp["ln1"], hh, cfg.norm_eps),
                cache, index)
            hh = hh + a
            hh = hh + L.apply_mlp(lp["mlp"],
                                  L.apply_rmsnorm(lp["ln2"], hh, cfg.norm_eps),
                                  cfg.act)
            return hh, nc
        h, new = jax.lax.scan(step, h, (params["layers"], caches["layers"]))
        caches = {"layers": new}
    elif fam == "moe":
        dec = (A.apply_mla_decode if cfg.attention == "mla"
               else A.apply_attention_decode)
        new_caches = {}
        if cfg.first_k_dense:
            def dstep(hh, xs):
                lp, cache = xs
                a, nc = dec(lp["attn"], cfg,
                            L.apply_rmsnorm(lp["ln1"], hh, cfg.norm_eps),
                            cache, index)
                hh = hh + a
                hh = hh + L.apply_mlp(lp["mlp"],
                                      L.apply_rmsnorm(lp["ln2"], hh, cfg.norm_eps),
                                      cfg.act)
                return hh, nc
            h, newd = jax.lax.scan(dstep, h, (params["dense_layers"],
                                              caches["dense_layers"]))
            new_caches["dense_layers"] = newd

        def mstep(hh, xs):
            lp, cache = xs
            a, nc = dec(lp["attn"], cfg,
                        L.apply_rmsnorm(lp["ln1"], hh, cfg.norm_eps), cache, index)
            hh = hh + a
            y, _ = M.apply_moe(lp["moe"], cfg,
                               L.apply_rmsnorm(lp["ln2"], hh, cfg.norm_eps))
            return hh + y, nc
        h, newm = jax.lax.scan(mstep, h, (params["layers"], caches["layers"]))
        new_caches["layers"] = newm
        caches = new_caches
    elif fam == "ssm":
        def step(hh, xs):
            lp, cache = xs
            y, nc = S.apply_ssm_decode(
                lp["ssm"], cfg, L.apply_rmsnorm(lp["ln"], hh, cfg.norm_eps), cache)
            return hh + y, nc
        h, new = jax.lax.scan(step, h, (params["layers"], caches["layers"]))
        caches = {"layers": new}
    elif fam == "hybrid":
        emb0 = h

        def inner(hh, xs):
            lp, cache = xs
            y, nc = S.apply_ssm_decode(
                lp["ssm"], cfg, L.apply_rmsnorm(lp["ln"], hh, cfg.norm_eps), cache)
            return hh + y, nc

        def group_step(hh, xs):
            gp, gcache, scache = xs
            hh, ncache = jax.lax.scan(inner, hh, (gp, gcache))
            sp = params["shared"]
            c = jnp.concatenate([hh, emb0], axis=-1)
            a, nsc = A.apply_attention_decode(
                sp["attn"], cfg, L.apply_rmsnorm(sp["ln1"], c, cfg.norm_eps),
                scache, index)
            hh = hh + a
            c2 = jnp.concatenate([hh, emb0], axis=-1)
            m = L.apply_rmsnorm(sp["ln2"], c2, cfg.norm_eps)
            m = jax.nn.silu(m @ sp["mlp"]["gate"].astype(hh.dtype)) * (m @ sp["mlp"]["up"].astype(hh.dtype))
            hh = hh + m @ sp["mlp"]["down"].astype(hh.dtype)
            return hh, (ncache, nsc)
        h, (ng, ns) = jax.lax.scan(group_step, h,
                                   (params["groups"], caches["groups"],
                                    caches["shared"]))
        new = {"groups": ng, "shared": ns}
        if "leftover" in params:
            h, nl = jax.lax.scan(inner, h, (params["leftover"], caches["leftover"]))
            new["leftover"] = nl
        caches = new
    elif fam == "encdec":
        def step(hh, xs):
            lp, scache, xcache = xs
            a, nc = A.apply_attention_decode(
                lp["self_attn"], cfg,
                L.apply_rmsnorm(lp["ln1"], hh, cfg.norm_eps), scache, index)
            hh = hh + a
            hh = hh + _cross_attention_decode(
                lp["cross_attn"], cfg,
                L.apply_rmsnorm(lp["ln_x"], hh, cfg.norm_eps),
                xcache["k"], xcache["v"])
            hh = hh + L.apply_mlp(lp["mlp"],
                                  L.apply_rmsnorm(lp["ln2"], hh, cfg.norm_eps),
                                  cfg.act)
            return hh, nc
        h, new = jax.lax.scan(step, h, (params["dec_layers"], caches["self"],
                                        caches["cross"]))
        caches = {"self": new, "cross": caches["cross"]}
    else:
        raise ValueError(fam)

    h = L.apply_rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _head(params, cfg, h), caches
