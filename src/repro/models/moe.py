"""Mixture-of-Experts with expert parallelism.

TPU adaptation (DESIGN.md §2): instead of the CUDA-style token-permutation
or the classic GShard one-hot dispatch einsum — whose (tokens x E x capacity)
one-hot tensors add O(tokens^2) *fake* FLOPs and O(GB) temporaries — we use a
**sort-based capacity-bucketed dispatch**: tokens are argsorted by expert id,
ranked within their expert, and scattered into an (E_local, C, D) VMEM-friendly
buffer; expert matmuls are a single dense (E,C,D)x(E,D,F) einsum (MXU-aligned);
the combine is a scatter-add. Zero matmul FLOPs are spent on dispatch.

Expert parallelism runs under ``shard_map``: activations arrive replicated
across the ``model`` axis (standard TP layout), each shard computes its
E/TP experts over the full local batch, and partial outputs are ``psum``-ed
over ``model``. (The §Perf hillclimb replaces replicated activations + psum
with sequence-sharded activations + all-to-all dispatch; see EXPERIMENTS.md.)

FSDP-compatible: if expert weights arrive d_model-sharded over ``data``
(DeepSeek-671B config), they are all-gathered per layer inside the shard_map
— exactly the FSDP weight-gather pattern.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.sharding.compat import shard_map

from repro.models import layers as L
from repro.sharding.ctx import axis_ctx, current_strategy, shard


def init_moe(key, cfg, dtype=jnp.float32):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": L.dense_init(ks[0], D, E, jnp.float32),  # router kept fp32
        "experts": {
            "gate": jax.vmap(lambda k: L.dense_init(k, D, F, dtype))(jax.random.split(ks[1], E)),
            "up": jax.vmap(lambda k: L.dense_init(k, D, F, dtype))(jax.random.split(ks[2], E)),
            "down": jax.vmap(lambda k: L.dense_init(k, F, D, dtype))(jax.random.split(ks[3], E)),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(ks[4], D, F * cfg.num_shared_experts, "swiglu", dtype)
    return p


def _route(p, cfg, x):
    """Returns (weights (B,S,k), idx (B,S,k), aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    if cfg.router_type == "sigmoid":                        # deepseek-v3
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, cfg.experts_per_token)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    # switch-style load-balance aux loss
    probs = jax.nn.softmax(logits, axis=-1)
    E = cfg.num_experts
    one_hot = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    f = jnp.mean(one_hot, axis=(0, 1))
    pbar = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * pbar) * cfg.aux_loss_coef
    return w.astype(x.dtype), idx, aux


def _capacity(tokens: int, k: int, num_experts: int, cf: float) -> int:
    c = int(tokens * k * cf / num_experts) + 1
    return max(8, ((c + 7) // 8) * 8)                      # 8-aligned slots


def _expert_compute_local(x2d, idx2d, w2d, gate, up, down, e0, e_local, cap):
    """Sort-based dispatch on one shard.

    x2d: (T, D); idx2d/w2d: (T, k); gate/up/down: (El, D, F)/(El, F, D).
    Returns (T, D) partial output for experts [e0, e0+El).
    """
    T, D = x2d.shape
    k = idx2d.shape[1]
    N = T * k
    flat_e = idx2d.reshape(N) - e0
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_w = w2d.reshape(N)

    in_range = (flat_e >= 0) & (flat_e < e_local)
    sort_key = jnp.where(in_range, flat_e, e_local)        # invalid -> end
    order = jnp.argsort(sort_key)                          # stable
    se = sort_key[order]
    stok = flat_tok[order]
    sw = flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(e_local), side="left")
    pos = jnp.arange(N, dtype=jnp.int32) - starts[jnp.clip(se, 0, e_local - 1)]
    keep = (se < e_local) & (pos < cap)
    dest = jnp.where(keep, se * cap + pos, e_local * cap)  # trash slot at end

    slot_tok = jnp.zeros((e_local * cap + 1,), jnp.int32).at[dest].set(stok)
    slot_w = jnp.zeros((e_local * cap + 1,), x2d.dtype).at[dest].set(
        jnp.where(keep, sw, 0).astype(x2d.dtype))
    xin = x2d[slot_tok[:-1]].reshape(e_local, cap, D)       # (El,C,D)

    h = jnp.einsum("ecd,edf->ecf", xin, gate)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xin, up)
    out = jnp.einsum("ecf,efd->ecd", h, down)               # (El,C,D)

    out2 = (out.reshape(e_local * cap, D) * slot_w[:-1, None])
    y = jnp.zeros((T, D), out2.dtype).at[slot_tok[:-1]].add(out2)
    return y


def _apply_moe_a2a(cfg, mesh, x2d, idx2d, w2d, ex):
    """Sequence-sharded EP with all-to-all dispatch (§Perf optimization).

    The shard_map boundary keeps the SAME layout as the surrounding layers
    (tokens sharded over data, replicated over model) — resharding at the
    boundary provokes XLA's "involuntary full rematerialization" (measured:
    a 5x collective blow-up). Each model shard instead SLICES its row range
    locally (free on replicated data), routes those T/tp tokens, exchanges
    fixed-capacity buckets with the expert owners via ``all_to_all``,
    computes its local experts, reverses the exchange, and ``all_gather``s
    the combined rows over ``model`` (1x gather in activation dtype vs the
    baseline's 2x fp32 all-reduce; dispatch wire ~ k*cf/tp of a full pass).
    """
    E, k = cfg.num_experts, cfg.experts_per_token
    tp = mesh.shape["model"]
    e_local = E // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    T, D_model = x2d.shape
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    t_local = T // (dp * tp)
    c_send = _capacity(t_local, k, tp, cfg.capacity_factor)  # per-dest bucket
    c_comp = _capacity(tp * c_send, 1, e_local, cfg.capacity_factor)

    fsdp = ("data" in mesh.shape and mesh.shape["data"] > 1
            and cfg.name.startswith("deepseek"))
    gspec = P("model", "data", None) if fsdp else P("model", None, None)
    dspec = P("model", None, "data") if fsdp else P("model", None, None)

    # 4D row layout (dp, tp, t_local, ...) keeps the device order natural, so
    # the boundary reshard is a local split/concat the partitioner transposes
    # to an all-gather — NOT a psum (and not the "involuntary full
    # rematerialization" a flat 256-way row sharding provoked)
    rspec = P(batch_axes if batch_axes else None, "model", None, None)
    x4 = x2d.reshape(dp, tp, t_local, D_model)
    idx4 = idx2d.reshape(dp, tp, t_local, k)
    w4 = w2d.reshape(dp, tp, t_local, k)

    def shard_fn(x_blk, idx_blk, w_blk, g, u, d):
        if fsdp:
            g = jax.lax.all_gather(g, "data", axis=1, tiled=True)
            u = jax.lax.all_gather(u, "data", axis=1, tiled=True)
            d = jax.lax.all_gather(d, "data", axis=2, tiled=True)
        x_ = x_blk[0, 0]
        idx_ = idx_blk[0, 0]
        w_ = w_blk[0, 0]
        t, D = x_.shape
        N = t * k
        flat_e = idx_.reshape(N)
        flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        flat_w = w_.reshape(N)
        dest = flat_e // e_local                          # owning shard
        order = jnp.argsort(dest)
        sdest, stok = dest[order], flat_tok[order]
        se, sw = flat_e[order], flat_w[order]
        starts = jnp.searchsorted(sdest, jnp.arange(tp), side="left")
        pos = jnp.arange(N, dtype=jnp.int32) - starts[jnp.clip(sdest, 0, tp - 1)]
        keep = pos < c_send
        slot = jnp.where(keep, sdest * c_send + pos, tp * c_send)

        # send buffers (trash slot at the end)
        x_pad = jnp.concatenate([x_, jnp.zeros((1, D), x_.dtype)], 0)
        s_tok = jnp.full((tp * c_send + 1,), t, jnp.int32).at[slot].set(stok)
        s_e = jnp.zeros((tp * c_send + 1,), jnp.int32).at[slot].set(se)
        s_w = jnp.zeros((tp * c_send + 1,), w_.dtype).at[slot].set(
            jnp.where(keep, sw, 0).astype(w_.dtype))
        s_x = x_pad[s_tok[:-1]].reshape(tp, c_send, D)
        s_e = s_e[:-1].reshape(tp, c_send)
        s_valid = (s_tok[:-1] < t).reshape(tp, c_send)

        r_x = jax.lax.all_to_all(s_x, "model", 0, 0, tiled=True)
        r_e = jax.lax.all_to_all(s_e, "model", 0, 0, tiled=True)
        r_v = jax.lax.all_to_all(s_valid, "model", 0, 0, tiled=True)

        e0 = jax.lax.axis_index("model") * e_local
        le = jnp.where(r_v, r_e - e0, e_local).reshape(tp * c_send, 1)
        ones = jnp.ones((tp * c_send, 1), x_.dtype)
        out = _expert_compute_local(r_x.reshape(tp * c_send, D),
                                    le.astype(jnp.int32), ones,
                                    g, u, d, 0, e_local, c_comp)
        out = jax.lax.all_to_all(out.reshape(tp, c_send, D), "model",
                                 0, 0, tiled=True)
        # combine: weighted scatter-add back to local tokens
        out2 = out.reshape(tp * c_send, D) * s_w[:-1, None]
        y = jnp.zeros((t + 1, D), out2.dtype).at[s_tok[:-1]].add(out2)
        return y[:-1][None, None]

    y4 = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rspec, rspec, rspec, gspec, gspec, dspec),
        out_specs=rspec, check_vma=False,
    )(x4, idx4, w4, ex["gate"], ex["up"], ex["down"])
    # pin the result back to the surrounding batch-over-data layout so the
    # row sharding doesn't propagate into the attention layers' backward
    return shard(y4.reshape(T, D_model), "batch", None)


def apply_moe(p, cfg, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux_loss)."""
    B, S, D = x.shape
    w, idx, aux = _route(p, cfg, x)
    x2d = x.reshape(B * S, D)
    idx2d = idx.reshape(B * S, -1)
    w2d = w.reshape(B * S, -1)
    E, k = cfg.num_experts, cfg.experts_per_token
    ex = p["experts"]

    mesh, _rules = axis_ctx()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    exp_rule = _rules.get("expert") if _rules else None
    ep_on = exp_rule == "model" or (isinstance(exp_rule, tuple)
                                    and "model" in exp_rule)
    strategy = current_strategy()
    if mesh is None or tp == 1 or E % tp != 0 or not ep_on:
        cap = _capacity(B * S, k, E, cfg.capacity_factor)
        y = _expert_compute_local(x2d, idx2d, w2d, ex["gate"], ex["up"],
                                  ex["down"], 0, E, cap)
    elif (strategy in ("moe_a2a", "moe_a2a_seqshard")
          and (B * S) % (tp * max(1, mesh.shape.get("data", 1)
                                  * mesh.shape.get("pod", 1))) == 0):
        y = _apply_moe_a2a(cfg, mesh, x2d, idx2d, w2d, ex)
    else:
        e_local = E // tp
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        bspec = P(batch_axes if batch_axes else None)

        # expert-weight specs mirror the param sharding rules (EP over model,
        # optional FSDP over data on the d_model dim)
        def wspec(d_axis):
            ax = [None, None, None]
            ax[0] = "model"
            if D % mesh.shape.get("data", 1) == 0 and mesh.shape.get("data", 1) > 1:
                ax[d_axis] = "data"
            return P(*ax)

        fsdp = "data" in mesh.shape and mesh.shape["data"] > 1 and cfg.name.startswith("deepseek")
        gspec = wspec(1) if fsdp else P("model", None, None)
        dspec = wspec(2) if fsdp else P("model", None, None)

        rs_ok = strategy == "moe_rs" and x2d.shape[0] % (
            tp * mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)) == 0

        def shard_fn(x2d_, idx2d_, w2d_, g, u, d):
            if fsdp:
                g = jax.lax.all_gather(g, "data", axis=1, tiled=True)
                u = jax.lax.all_gather(u, "data", axis=1, tiled=True)
                d = jax.lax.all_gather(d, "data", axis=2, tiled=True)
            e0 = jax.lax.axis_index("model") * e_local
            # capacity from the LOCAL token count (x2d_ is the local block)
            cap = _capacity(x2d_.shape[0], k, E, cfg.capacity_factor)
            y = _expert_compute_local(x2d_, idx2d_, w2d_, g, u, d,
                                      e0, e_local, cap)
            if rs_ok:
                # §Perf: reduce-scatter + bf16 all-gather — <=1/2 the wire
                # of the all-reduce (its transpose is the same pair). The
                # optimization_barrier stops XLA's collective re-association
                # pass from fusing the pair straight back into an all-reduce.
                part = jax.lax.psum_scatter(y, "model", scatter_dimension=0,
                                            tiled=True)
                part = jax.lax.optimization_barrier(
                    part.astype(jnp.bfloat16))
                return jax.lax.all_gather(part, "model",
                                          axis=0, tiled=True).astype(y.dtype)
            return jax.lax.psum(y, "model")

        y = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(bspec, bspec, bspec, gspec, gspec, dspec),
            out_specs=bspec, check_vma=False,
        )(x2d, idx2d, w2d, ex["gate"], ex["up"], ex["down"])

    if "shared" in p:
        y = y + L.apply_mlp(p["shared"], x2d, "swiglu")
    return y.reshape(B, S, D).astype(x.dtype), aux
