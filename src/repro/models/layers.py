"""Core functional layers (no flax): norms, MLP, RoPE, embeddings.

Params are nested dicts of jnp arrays. ``init_*`` builds params; ``apply_*``
consumes them. Layer stacks are created with ``stack_init`` (vmapped init)
so model bodies can ``lax.scan`` over the stacked leading axis — this keeps
the HLO small (critical for the 61-layer 671B dry-run compile) and matches
the TPU-idiomatic MaxText pattern.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def stack_init(init_fn: Callable, key, n: int):
    """vmap an init over n split keys -> stacked params with leading dim n."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def apply_rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLP: gated (swiglu / geglu) or plain (gelu)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, d_ff, d_model, dtype)}
    if act in ("swiglu", "geglu"):
        p["gate"] = dense_init(k1, d_model, d_ff, dtype)
        p["up"] = dense_init(k3, d_model, d_ff, dtype)
    else:
        p["up"] = dense_init(k1, d_model, d_ff, dtype)
    return p


def apply_mlp(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["gate"], approximate=True) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"], approximate=True)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1, 2), inline=True)
def _rope_tables(positions, dim: int, theta: float):
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dim = x.shape[-1]
    cos, sin = _rope_tables(positions, dim, theta)     # (..., seq, dim/2)
    cos = cos[..., None, :]                            # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": embed_init(key, vocab, dim, dtype)}


def apply_embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def apply_lm_head(embed_params, x, head_params=None):
    """Tied (embed transpose) or untied head."""
    if head_params is not None:
        return x @ head_params["w"]
    table = embed_params["table"]
    return x @ table.T.astype(x.dtype)
