"""Mamba2 SSD (state-space duality) block — chunked scan + O(1)-state decode.

The chunked SSD algorithm (arXiv:2405.21060) splits the sequence into chunks
of length Q: within-chunk interactions are a (Q x Q) masked quadratic term
(MXU-friendly matmuls), and cross-chunk interactions flow through a recurrent
(H, P, N) state carried by a short ``lax.scan`` over chunks. This is the
TPU-native formulation — the CUDA kernel's warp-level selective scan is
replaced by matmuls the MXU executes at full throughput.

The projection of the input into (z | x | B | C | dt) is split into separate
matmuls (mathematically identical to the fused in_proj of the reference
implementation) so each output lands on a sharding-friendly dimension —
fused-projection slicing would cut across TP shard boundaries (DESIGN.md §2).

``repro.kernels.ssd_scan`` provides the Pallas version of the chunk scan;
this module is the pure-jnp oracle path used by dry-runs and CPU tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.ctx import shard


def dims(cfg) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_ngroups, cfg.ssm_state


def init_ssm(key, cfg, dtype=jnp.float32):
    D = cfg.d_model
    d_in, H, G, N = dims(cfg)
    K = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "in_z": L.dense_init(ks[0], D, d_in, dtype),
        "in_x": L.dense_init(ks[1], D, d_in, dtype),
        "in_B": L.dense_init(ks[2], D, G * N, dtype),
        "in_C": L.dense_init(ks[3], D, G * N, dtype),
        "in_dt": L.dense_init(ks[4], D, H, dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "conv_x": (jax.random.normal(ks[5], (K, d_in), jnp.float32) * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (K, G * N), jnp.float32) * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (K, G * N), jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "gate_norm": L.init_rmsnorm(d_in, dtype),
        "out": L.dense_init(jax.random.fold_in(key, 99), d_in, D, dtype),
    }


def _causal_conv(u, w):
    """Depthwise causal conv. u: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(K):                                   # K=4: unrolled taps
        out = out + pad[:, i: i + u.shape[1], :] * w[i][None, None, :]
    return out


def ssd_chunked(xh, dt, a_log, Bm, Cm, chunk: int):
    """Chunked SSD scan (pure jnp oracle).

    xh: (B,S,H,P) inputs; dt: (B,S,H) positive step sizes;
    a_log: (H,) with A = -exp(a_log); Bm/Cm: (B,S,G,N).
    Returns y: (B,S,H,P) and final state (B,H,P,N).
    """
    Bsz, S, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    A = -jnp.exp(a_log.astype(jnp.float32))              # (H,) negative
    dA = dt.astype(jnp.float32) * A[None, None, :]       # (B,S,H) log-decay <0
    xbar = xh.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # reshape to chunks
    dA_c = dA.reshape(Bsz, nc, Q, H)
    x_c = xbar.reshape(Bsz, nc, Q, H, Pd)
    B_c = jnp.repeat(Bm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N), rep, axis=3)
    C_c = jnp.repeat(Cm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N), rep, axis=3)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    # scan over chunks: only ONE chunk's (Q x Q) quadratic term is live at a
    # time (the all-chunks einsum materialized B*nc*H*Q*Q fp32 — 17 GB/layer
    # for zamba2's train_4k shard — and dominated temp memory; §Perf)
    def chunk_fn(state, inp):
        dA_k, x_k, B_k, C_k = inp                        # (B,Q,H), (B,Q,H,P), (B,Q,H,N)
        cum = jnp.cumsum(dA_k, axis=1)                   # (B,Q,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]    # (B,Qt,Qs,H)
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        Lmat = Lmat.transpose(0, 3, 1, 2)                # (B,H,Qt,Qs)
        CB = jnp.einsum("bthn,bshn->bhts", C_k, B_k)     # (B,H,Qt,Qs)
        y = jnp.einsum("bhts,bshp->bthp", CB * Lmat, x_k)
        decay_in = jnp.exp(cum)                          # exp(l_t)
        y += jnp.einsum("bthn,bth,bhnp->bthp", C_k, decay_in, state)
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)     # (B,Q,H)
        S_chunk = jnp.einsum("bshn,bsh,bshp->bhnp", B_k, decay_to_end, x_k)
        new = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + S_chunk
        return new, y

    init = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    final, ys = jax.lax.scan(
        chunk_fn, init,
        (dA_c.transpose(1, 0, 2, 3), x_c.transpose(1, 0, 2, 3, 4),
         B_c.transpose(1, 0, 2, 3, 4), C_c.transpose(1, 0, 2, 3, 4)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, Pd)
    return y.astype(xh.dtype), final.transpose(0, 1, 3, 2)  # state (B,H,P,N)


def apply_ssm_full(p, cfg, x):
    """x: (B,S,D) -> (B,S,D). Full-sequence chunked SSD."""
    B, S, D = x.shape
    d_in, H, G, N = dims(cfg)
    dt_ = x.dtype
    z = x @ p["in_z"].astype(dt_)
    xs = _causal_conv(x @ p["in_x"].astype(dt_), p["conv_x"].astype(dt_))
    Bm = _causal_conv(x @ p["in_B"].astype(dt_), p["conv_B"].astype(dt_))
    Cm = _causal_conv(x @ p["in_C"].astype(dt_), p["conv_C"].astype(dt_))
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus((x @ p["in_dt"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"][None, None, :])

    xh = shard(xs.reshape(B, S, H, cfg.ssm_head_dim), "batch", None, "ssm_heads", None)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    y, _ = ssd_chunked(xh, dt, p["A_log"], Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = L.apply_rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out"].astype(dt_)


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    d_in, H, G, N = dims(cfg)
    K = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, K - 1, G * N), dtype),
        "conv_C": jnp.zeros((batch, K - 1, G * N), dtype),
    }


def _conv_step(u1, conv_state, w):
    """u1: (B,1,C); conv_state: (B,K-1,C); w: (K,C)."""
    window = jnp.concatenate([conv_state, u1], axis=1)    # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    return out, window[:, 1:, :]


def apply_ssm_decode(p, cfg, x, cache):
    """x: (B,1,D); O(1)-state recurrent decode step."""
    B = x.shape[0]
    d_in, H, G, N = dims(cfg)
    Pd = cfg.ssm_head_dim
    dt_ = x.dtype
    z = x @ p["in_z"].astype(dt_)
    xs_raw = x @ p["in_x"].astype(dt_)
    Bm_raw = x @ p["in_B"].astype(dt_)
    Cm_raw = x @ p["in_C"].astype(dt_)
    xs, cs_x = _conv_step(xs_raw, cache["conv_x"], p["conv_x"].astype(dt_))
    Bm, cs_B = _conv_step(Bm_raw, cache["conv_B"], p["conv_B"].astype(dt_))
    Cm, cs_C = _conv_step(Cm_raw, cache["conv_C"], p["conv_C"].astype(dt_))
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus((x @ p["in_dt"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"][None, None, :])[:, 0]        # (B,H)

    xh = xs.reshape(B, H, Pd).astype(jnp.float32)
    Bv = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Cv = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                      # (B,H)
    state = cache["state"] * decay[:, :, None, None]
    state = state + jnp.einsum("bhp,bhn,bh->bhpn", xh, Bv, dt)
    y = jnp.einsum("bhpn,bhn->bhp", state, Cv)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(dt_)
    y = L.apply_rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out"].astype(dt_)
    return out, {"state": state, "conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C}
