"""Attention: GQA/MHA and MLA (DeepSeek latent), full + decode paths.

Full-sequence attention is *blockwise* (lax.scan over KV blocks with online
softmax — flash-attention semantics at the XLA level) so that 32k-token
prefill never materializes the (S x S) score matrix. The per-block body is
wrapped in ``jax.checkpoint`` so the autodiff backward recomputes block
scores instead of saving O(S^2) residuals.

Decode attends a single new token against a KV cache laid out
(batch, kv_heads, seq, head_dim) so the sharding resolver prefers
head-sharding and falls back to split-KV sequence sharding when
``kv_heads % TP != 0`` (flash-decoding pattern; see DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.ctx import shard

KV_BLOCK = 1024


# ---------------------------------------------------------------------------
# GQA / MHA
# ---------------------------------------------------------------------------

def init_attention(key, cfg, d_in: Optional[int] = None, dtype=jnp.float32):
    d_in = d_in or cfg.d_model
    hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(k1, d_in, H * hd, dtype),
        "wk": L.dense_init(k2, d_in, KH * hd, dtype),
        "wv": L.dense_init(k3, d_in, KH * hd, dtype),
        "wo": L.dense_init(k4, H * hd, cfg.d_model, dtype),
    }


def _block_attn(q, k, v, qpos, kpos, prefix_len, scale):
    """One KV block of online-softmax attention.

    q: (B, H, Sq, hd); k/v: (B, H, Bk, hd); returns (acc, m, l) update terms.
    """
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    mask = qpos[None, None, :, None] >= kpos[None, None, None, :]
    if prefix_len is not None:
        bidir = kpos[None, None, None, :] < prefix_len
        mask = jnp.logical_or(mask, bidir)
    s = jnp.where(mask, s, -1e30)
    m_blk = jnp.max(s, axis=-1)                      # (B,H,Sq)
    p = jnp.exp(s - m_blk[..., None])
    l_blk = jnp.sum(p, axis=-1)
    o_blk = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v).astype(jnp.float32)
    return o_blk, m_blk, l_blk


def _merge(carry, o_blk, m_blk, l_blk):
    acc, m, l = carry
    m_new = jnp.maximum(m, m_blk)
    a = jnp.exp(m - m_new)
    b = jnp.exp(m_blk - m_new)
    acc = acc * a[..., None] + o_blk * b[..., None]
    l = l * a + l_blk * b
    return acc, m_new, l


def blockwise_attention(q, k, v, qpos, kpos, prefix_len=None,
                        block: int = KV_BLOCK, scale: Optional[float] = None):
    """q: (B,H,Sq,hd), k/v: (B,H,Sk,hd). Returns (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    block = min(block, Sk)
    pad = (-Sk) % block
    if pad:  # pad keys; sentinel positions are masked out by the causal test
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=jnp.int32(2 ** 30))
        Sk += pad
    nblk = Sk // block

    kb = k.reshape(B, H, nblk, block, hd).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, nblk, block, v.shape[-1]).transpose(2, 0, 1, 3, 4)
    pb = kpos.reshape(nblk, block)

    @jax.checkpoint
    def body(carry, inp):
        kblk, vblk, kposblk = inp
        o_blk, m_blk, l_blk = _block_attn(q, kblk, vblk, qpos, kposblk,
                                          prefix_len, scale)
        return _merge(carry, o_blk, m_blk, l_blk), None

    acc0 = jnp.zeros((B, H, Sq, v.shape[-1]), jnp.float32)
    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def apply_attention_full(p, cfg, x, positions, prefix_len=None):
    """x: (B,S,D_in) -> (B,S,D). Causal (or prefix-LM) full attention."""
    B, S, _ = x.shape
    hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, KH, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, KH, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if KH != H:
        rep = H // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = shard(q.transpose(0, 2, 1, 3), "batch", "heads", "seq_q", None)
    k = shard(k.transpose(0, 2, 1, 3), "batch", "heads", None, None)
    v = shard(v.transpose(0, 2, 1, 3), "batch", "heads", None, None)
    qpos = positions[0] if positions.ndim == 2 else positions
    out = blockwise_attention(q, k, v, qpos, qpos, prefix_len)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return out @ p["wo"].astype(dt)


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd, KH = cfg.head_dim, cfg.num_kv_heads
    return {
        "k": jnp.zeros((batch, KH, max_len, hd), dtype),
        "v": jnp.zeros((batch, KH, max_len, hd), dtype),
    }


def apply_attention_decode(p, cfg, x, cache, index):
    """x: (B,1,D_in); cache k/v: (B,KH,S,hd); index: scalar current position.

    Returns (out (B,1,D), new_cache).
    """
    B = x.shape[0]
    hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, 1, KH, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, 1, KH, hd)
    pos = jnp.full((B, 1), index, jnp.int32)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)

    k_c = jax.lax.dynamic_update_slice(
        cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype), (0, 0, index, 0))
    v_c = jax.lax.dynamic_update_slice(
        cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype), (0, 0, index, 0))
    k_c = shard(k_c, "batch", "kv_heads", "kv_seq", None)
    v_c = shard(v_c, "batch", "kv_heads", "kv_seq", None)

    G = H // KH
    qg = q.reshape(B, KH, G, hd)                       # (B,KH,G,hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   k_c.astype(jnp.float32)) * hd ** -0.5
    S = k_c.shape[2]
    valid = jnp.arange(S)[None, None, None, :] <= index
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", w.astype(v_c.dtype), v_c)
    o = o.reshape(B, 1, H * hd).astype(dt)
    return o @ p["wo"].astype(dt), {"k": k_c, "v": v_c}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": L.dense_init(ks[0], D, qr, dtype),
        "q_norm": L.init_rmsnorm(qr, dtype),
        "wq_b": L.dense_init(ks[1], qr, H * (nope + rope), dtype),
        "wkv_a": L.dense_init(ks[2], D, kvr + rope, dtype),
        "kv_norm": L.init_rmsnorm(kvr, dtype),
        "wkv_b": L.dense_init(ks[3], kvr, H * (nope + vd), dtype),
        "wo": L.dense_init(ks[4], H * vd, D, dtype),
    }


def _mla_qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    q = L.apply_rmsnorm(p["q_norm"], x @ p["wq_a"].astype(dt), cfg.norm_eps)
    q = (q @ p["wq_b"].astype(dt)).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"].astype(dt)                    # (B,S,kvr+rope)
    c_kv = L.apply_rmsnorm(p["kv_norm"], kv[..., : cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][..., None, :]  # (B,S,1,rope)
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def apply_mla_full(p, cfg, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)

    kvb = p["wkv_b"].astype(dt).reshape(cfg.kv_lora_rank, H, nope + vd)
    k_nope = jnp.einsum("bsc,chn->bshn", c_kv, kvb[..., :nope])
    v = jnp.einsum("bsc,chn->bshn", c_kv, kvb[..., nope:])
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)

    q = shard(q.transpose(0, 2, 1, 3), "batch", "heads", "seq_q", None)
    k = shard(k.transpose(0, 2, 1, 3), "batch", "heads", None, None)
    v = shard(v.transpose(0, 2, 1, 3), "batch", "heads", None, None)
    qpos = positions[0] if positions.ndim == 2 else positions
    out = blockwise_attention(q, k, v, qpos, qpos,
                              scale=(nope + rope) ** -0.5)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * vd)
    return out @ p["wo"].astype(dt)


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """MLA caches the COMPRESSED latent (this is the point of MLA)."""
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def apply_mla_decode(p, cfg, x, cache, index):
    """Absorbed-matmul MLA decode: attends in latent space, O(kv_lora) cache."""
    B = x.shape[0]
    H = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    pos = jnp.full((B, 1), index, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, cfg, x, pos)

    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, index, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new[:, :, 0, :].astype(cache["k_rope"].dtype),
        (0, index, 0))
    c_kv = shard(c_kv, "batch", "kv_seq", None)
    k_rope = shard(k_rope, "batch", "kv_seq", None)

    kvb = p["wkv_b"].astype(dt).reshape(cfg.kv_lora_rank, H, nope + vd)
    w_uk, w_uv = kvb[..., :nope], kvb[..., nope:]
    # absorb W_uk into the query -> latent-space scores
    q_lat = jnp.einsum("bshn,chn->bshc", q_nope, w_uk)          # (B,1,H,kvr)
    s = jnp.einsum("bshc,btc->bhst", q_lat.astype(jnp.float32),
                   c_kv.astype(jnp.float32))
    s += jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                    k_rope.astype(jnp.float32))
    s *= (nope + rope) ** -0.5
    Smax = c_kv.shape[1]
    valid = jnp.arange(Smax)[None, None, None, :] <= index
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btc->bshc", w.astype(c_kv.dtype), c_kv)  # latent ctx
    o = jnp.einsum("bshc,chn->bshn", ctx.astype(dt), w_uv)          # (B,1,H,vd)
    o = o.reshape(B, 1, H * vd)
    return o @ p["wo"].astype(dt), {"c_kv": c_kv, "k_rope": k_rope}
